"""R6 — slot-protocol conformance between engines and the scheduler.

``runtime/scheduler.py`` drives engines purely through ``sched_*``
methods.  The required set is *scraped from the scheduler's own call
sites* (a direct ``eng.sched_x(...)`` call is a hard requirement; a
``getattr(eng, "sched_x", default)`` / ``hasattr`` probe marks an
optional extension), then cross-checked against the declared
``SchedulableEngine`` Protocol in ``runtime/engine.py``:

* every public class exposing *any* ``sched_*`` method (directly or by
  inheritance) must implement the full required set — a partial engine
  passes construction and dies at the first boundary that exercises the
  missing slot call;
* the Protocol must declare every scraped-required method, so the typed
  contract can never silently lag the scheduler's actual usage.

Private mix-ins (``_Foo``) and Protocol classes themselves are exempt.
"""
from __future__ import annotations

import ast
from typing import List, Set, Tuple

from repro.analysis import callgraph
from repro.analysis.core import Finding, Project, register_rule
from repro.analysis.callgraph import dotted


def _scrape(files) -> Tuple[Set[str], Set[str]]:
    required: Set[str] = set()
    optional: Set[str] = set()
    for f in files:
        for node in ast.walk(f.tree):
            if not isinstance(node, ast.Call):
                continue
            if isinstance(node.func, ast.Attribute) and \
                    node.func.attr.startswith("sched_"):
                required.add(node.func.attr)
            d = dotted(node.func)
            if d in ("getattr", "hasattr") and len(node.args) >= 2 and \
                    isinstance(node.args[1], ast.Constant) and \
                    isinstance(node.args[1].value, str) and \
                    node.args[1].value.startswith("sched_"):
                optional.add(node.args[1].value)
    required -= optional
    return required, optional


@register_rule(
    "R6",
    "slot-protocol conformance: engines exposing sched_* implement the "
    "full set the scheduler calls, matching the SchedulableEngine "
    "Protocol")
def rule_protocol(project: Project) -> List[Finding]:
    idx = callgraph.get_index(project)
    out: List[Finding] = []

    def add(rel, line, msg):
        out.append(Finding(path=rel, line=line, rule="R6", message=msg))

    sched_files = [f for f in project.files
                   if f.rel.endswith("scheduler.py")]
    scrape_from = sched_files or project.files
    required, optional = _scrape(scrape_from)
    if not required:
        return out

    protocols = []              # (ClassInfo, member-name set)
    engines = []                # (ClassInfo, all-method set, own sched_*)
    for mod in idx.modules.values():
        for ci in mod.classes.values():
            is_protocol = any(b.split(".")[-1] == "Protocol"
                              for b in ci.base_names)
            methods = set(idx.class_methods(ci))
            sched = {m for m in methods if m.startswith("sched_")}
            if is_protocol:
                if sched:
                    protocols.append((ci, methods))
                continue
            if ci.name.startswith("_"):
                continue
            if sched:
                engines.append((ci, methods))

    for ci, methods in engines:
        missing = sorted(required - methods)
        if missing:
            add(ci.file.rel, ci.node.lineno,
                f"engine `{ci.name}` exposes sched_* but is missing "
                f"{missing} — required by runtime/scheduler.py call "
                f"sites (optional extensions: {sorted(optional)})")

    for ci, members in protocols:
        undeclared = sorted(required - members)
        if undeclared:
            add(ci.file.rel, ci.node.lineno,
                f"scheduler call sites require {undeclared} but Protocol "
                f"`{ci.name}` does not declare them — the typed contract "
                f"lags the scheduler's actual usage")
    return out
