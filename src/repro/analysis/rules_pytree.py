"""R5 — pytree registration completeness.

Every class that crosses the jit boundary as data (``Tree``,
``DecodeStrategy``, ``SpecState``, the KV caches, ``AdamWState``) must
be a registered pytree, and the registration must cover every declared
field: a field missing from ``data_fields``/``meta_fields`` silently
vanishes on the first ``tree_map``/donated round-trip — the engine then
decodes with a stale or default value and no exception is raised.

Checks:
* ``register_dataclass`` (direct call, ``@partial(...)`` decorator, or a
  one-hop helper decorator like ``tree.py``'s ``_register_tree``):
  ``data_fields + meta_fields`` must equal the dataclass's declared
  fields — nothing missing, nothing unknown.
* ``register_pytree_node(cls, flatten, unflatten)``: the flatten
  function must read every ``__init__``-assigned (or annotated) field.
* Any project ``@dataclass`` *constructed* inside jit-reachable code
  must be registered (an unregistered dataclass is a trace error on the
  paths that build it).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis import callgraph
from repro.analysis.core import Finding, Project, register_rule
from repro.analysis.callgraph import ClassInfo, dotted


def _str_list(node) -> Optional[List[str]]:
    if isinstance(node, (ast.List, ast.Tuple, ast.Set)):
        out = []
        for e in node.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, str):
                out.append(e.value)
            else:
                return None
        return out
    return None


def _reg_fields(call: ast.Call) -> Optional[Tuple[List[str], List[str]]]:
    """(data_fields, meta_fields) from a register_dataclass-ish call."""
    data = meta = None
    for kw in call.keywords:
        if kw.arg == "data_fields":
            data = _str_list(kw.value)
        elif kw.arg == "meta_fields":
            meta = _str_list(kw.value)
    if data is None and meta is None:
        return None
    return (data or [], meta or [])


def _class_fields(ci: ClassInfo) -> List[str]:
    """Declared dataclass fields (annotated, non-ClassVar), else
    ``__init__`` self-assignments."""
    fields = []
    for node in ci.node.body:
        if isinstance(node, ast.AnnAssign) and \
                isinstance(node.target, ast.Name):
            ann = dotted(node.annotation) or ""
            if "ClassVar" not in ann:
                fields.append(node.target.id)
    if fields:
        return fields
    init = ci.methods.get("__init__")
    if init is not None:
        for node in ast.walk(init.node):
            if isinstance(node, ast.Assign):
                for t in node.targets:
                    if isinstance(t, ast.Attribute) and \
                            isinstance(t.value, ast.Name) and \
                            t.value.id == "self" and \
                            t.attr not in fields:
                        fields.append(t.attr)
    return fields


def _is_dataclass(ci: ClassInfo) -> bool:
    for dec in ci.node.decorator_list:
        d = dotted(dec.func) if isinstance(dec, ast.Call) else dotted(dec)
        if d in ("dataclass", "dataclasses.dataclass"):
            return True
    return False


@register_rule(
    "R5",
    "pytree completeness: registered pytrees flatten every field; "
    "dataclasses built under jit must be registered")
def rule_pytree(project: Project) -> List[Finding]:
    idx = callgraph.get_index(project)
    out: List[Finding] = []

    def add(rel, line, msg):
        out.append(Finding(path=rel, line=line, rule="R5", message=msg))

    # helper decorators: module functions whose body registers their
    # argument (tree.py's `_register_tree`)
    helper_fields: Dict[str, Tuple[List[str], List[str]]] = {}
    for mod in idx.modules.values():
        for name, fi in mod.funcs.items():
            if isinstance(fi.node, ast.Lambda):
                continue
            for node in ast.walk(fi.node):
                if isinstance(node, ast.Call):
                    d = dotted(node.func) or ""
                    args_d = [dotted(a) or "" for a in node.args]
                    if d.endswith("register_dataclass") or \
                            any(a.endswith("register_dataclass")
                                for a in args_d):
                        fields = _reg_fields(node)
                        if fields is not None:
                            helper_fields[f"{mod.name}.{name}"] = fields

    registered: Dict[str, Tuple[ClassInfo, Optional[Tuple[List[str],
                                                          List[str]]],
                                int]] = {}

    def register(ci: ClassInfo, fields, line):
        registered[f"{ci.module.name}.{ci.name}"] = (ci, fields, line)

    for mod in idx.modules.values():
        # decorator-registered classes
        for ci in mod.classes.values():
            for dec in ci.node.decorator_list:
                if isinstance(dec, ast.Call):
                    d = dotted(dec.func) or ""
                    args_d = [dotted(a) or "" for a in dec.args]
                    if d.endswith("register_dataclass") or (
                            d.endswith("partial") and any(
                                a.endswith("register_dataclass")
                                for a in args_d)):
                        register(ci, _reg_fields(dec), dec.lineno)
                else:
                    d = dotted(dec) or ""
                    # one-hop helper decorator
                    for hname, fields in helper_fields.items():
                        if hname.split(".")[-1] == d.split(".")[-1]:
                            register(ci, fields, ci.node.lineno)
        # direct register_dataclass / register_pytree_node calls
        for node in ast.walk(mod.file.tree):
            if not isinstance(node, ast.Call):
                continue
            d = dotted(node.func) or ""
            if d.endswith("register_dataclass") and node.args and \
                    isinstance(node.args[0], ast.Name):
                ci = idx.resolve_class(mod, node.args[0].id)
                if ci is not None:
                    register(ci, _reg_fields(node), node.lineno)
            elif d.endswith("register_pytree_node") and len(node.args) >= 2 \
                    and isinstance(node.args[0], ast.Name):
                ci = idx.resolve_class(mod, node.args[0].id)
                if ci is None:
                    continue
                register(ci, None, node.lineno)
                flat = node.args[1]
                flat_fi = None
                if isinstance(flat, ast.Name):
                    flat_fi = mod.funcs.get(flat.id)
                if isinstance(flat, ast.Lambda):
                    flat_fi = callgraph.FuncInfo(
                        node=flat, file=mod.file,
                        qualname=f"<lambda L{flat.lineno}>", parent=mod)
                if flat_fi is None or not flat_fi.params:
                    continue
                p0 = flat_fi.params[0]
                seen_attrs: Set[str] = set()
                walk_root = flat_fi.node.body if \
                    isinstance(flat_fi.node, ast.Lambda) else flat_fi.node
                for sub in ast.walk(walk_root):
                    if isinstance(sub, ast.Attribute) and \
                            isinstance(sub.value, ast.Name) and \
                            sub.value.id == p0:
                        seen_attrs.add(sub.attr)
                missing = [x for x in _class_fields(ci)
                           if x not in seen_attrs]
                for x in missing:
                    add(mod.file.rel, node.lineno,
                        f"register_pytree_node flatten for `{ci.name}` "
                        f"never reads field `{x}` — it is dropped on "
                        f"every flatten/unflatten round-trip")

    # completeness of register_dataclass field lists
    for ci, fields, line in registered.values():
        if fields is None:
            continue
        data, meta = fields
        declared = set(data) | set(meta)
        cls_fields = _class_fields(ci)
        for x in cls_fields:
            if x not in declared:
                add(ci.file.rel, line,
                    f"field `{x}` of registered pytree `{ci.name}` is in "
                    f"neither data_fields nor meta_fields — it is lost "
                    f"on the first tree_map/donated round-trip")
        for x in declared:
            if x not in cls_fields:
                add(ci.file.rel, line,
                    f"registration of `{ci.name}` lists unknown field "
                    f"`{x}` (declared fields: {sorted(cls_fields)})")

    # dataclasses constructed inside jit-reachable code must be registered
    reg_names = {k.split(".")[-1] for k in registered}
    flagged = set()
    for fi in idx.reached_from_jit():
        mod = idx._module_of(fi)
        if mod is None:
            continue
        body = [fi.node.body] if isinstance(fi.node, ast.Lambda) \
            else list(fi.node.body)
        for stmt in body:
            for node in ast.walk(stmt):
                if not isinstance(node, ast.Call) or \
                        not isinstance(node.func, ast.Name):
                    continue
                ci = idx.resolve_class(mod, node.func.id)
                if ci is None or not _is_dataclass(ci):
                    continue
                if ci.name not in reg_names and \
                        (ci.file.rel, ci.name) not in flagged:
                    flagged.add((ci.file.rel, ci.name))
                    add(fi.file.rel, node.lineno,
                        f"dataclass `{ci.name}` is constructed in "
                        f"jit-reachable `{fi.qualname}` but is not a "
                        f"registered pytree — tracing it will fail or "
                        f"silently treat it as a leaf")
    return out
