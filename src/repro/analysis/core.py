"""reprolint core: findings, suppressions, baseline, file walking, registry.

Everything here is plain stdlib — the linter must be runnable in any
environment that can parse the source tree, jax installed or not.

A rule is a callable ``rule(project) -> list[Finding]`` registered via
``@register_rule``.  ``Project`` owns the parsed ASTs (one ``SourceFile``
per module) so every rule shares one parse; rules that need cross-module
resolution use ``repro.analysis.callgraph`` on top of it.

Suppressions
------------
``# reprolint: disable=R1`` (or ``disable=R1,R4``) on the flagged line —
or the line directly above it, for statements whose flagged node starts
on a wrapped line — silences those rules for that line.
``# reprolint: disable-file=R3`` anywhere in a file's first 20 lines
silences a rule for the whole file.

Baseline
--------
Grandfathered findings live in a committed baseline file (one canonical
key per line: ``relpath::RULE::message``; line numbers are deliberately
excluded so unrelated edits don't invalidate it).  ``lint.py
--write-baseline`` regenerates it; the lint exits nonzero only for
findings that are neither suppressed nor baselined.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence

_RULE_LIST = r"([A-Z][A-Z0-9]*(?:\s*,\s*[A-Z][A-Z0-9]*)*)"
_SUPPRESS_RE = re.compile(r"#\s*reprolint:\s*disable=" + _RULE_LIST)
_SUPPRESS_FILE_RE = re.compile(r"#\s*reprolint:\s*disable-file=" + _RULE_LIST)


@dataclasses.dataclass(frozen=True)
class Finding:
    path: str                  # repo-relative (or as-given) file path
    line: int                  # 1-based line of the offending node
    rule: str                  # "R1".."R9"
    message: str               # human-readable, symbol-anchored

    def render(self) -> str:
        return f"{self.path}:{self.line} {self.rule} {self.message}"

    @property
    def key(self) -> str:
        """Line-number-free identity used by the baseline file."""
        return f"{self.path}::{self.rule}::{self.message}"


class SourceFile:
    """One parsed module: source text, AST, and per-line suppressions."""

    def __init__(self, path: Path, rel: str, text: str):
        self.path = path
        self.rel = rel
        self.text = text
        self.lines = text.splitlines()
        self.tree = ast.parse(text, filename=str(path))
        # line -> set of rule ids disabled there
        self.suppressed: Dict[int, set] = {}
        self.file_suppressed: set = set()
        for i, line in enumerate(self.lines, start=1):
            m = _SUPPRESS_RE.search(line)
            if m:
                rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
                self.suppressed[i] = rules
            if i <= 20:
                m = _SUPPRESS_FILE_RE.search(line)
                if m:
                    self.file_suppressed |= {
                        r.strip() for r in m.group(1).split(",") if r.strip()}

    def is_suppressed(self, line: int, rule: str) -> bool:
        if rule in self.file_suppressed:
            return True
        for ln in (line, line - 1):
            if rule in self.suppressed.get(ln, ()):
                return True
        return False


class Project:
    """The file set under analysis, parsed once and shared by all rules."""

    def __init__(self, files: Sequence[SourceFile]):
        self.files = list(files)
        self.by_rel: Dict[str, SourceFile] = {f.rel: f for f in self.files}

    def find(self, suffix: str) -> Optional[SourceFile]:
        """The unique file whose relative path ends with ``suffix``."""
        hits = [f for f in self.files if f.rel.endswith(suffix)]
        return hits[0] if len(hits) == 1 else None


# --------------------------------------------------------------------------
# rule registry
# --------------------------------------------------------------------------
RULES: Dict[str, Callable[[Project], List[Finding]]] = {}
RULE_DOC: Dict[str, str] = {}


def register_rule(rule_id: str, doc: str):
    def deco(fn):
        RULES[rule_id] = fn
        RULE_DOC[rule_id] = doc
        return fn
    return deco


def _ensure_rules_loaded() -> None:
    # imported lazily so `import repro.analysis.core` has no rule deps
    from repro.analysis import (rules_donation, rules_hostsync,  # noqa: F401
                                rules_kernelbounds, rules_locks,
                                rules_model, rules_protocol,
                                rules_purity, rules_pytree,
                                rules_retrace)


# --------------------------------------------------------------------------
# file collection + entry point
# --------------------------------------------------------------------------
_SKIP_DIRS = {"__pycache__", ".git", ".pytest_cache", "node_modules"}


def collect_files(paths: Sequence, *, root: Optional[Path] = None
                  ) -> List[SourceFile]:
    """Parse every ``.py`` under ``paths`` (files or directories).  ``root``
    anchors the relative paths used in findings/baselines (default: the
    common parent of each given path)."""
    out: List[SourceFile] = []
    for p in paths:
        p = Path(p)
        base = root or (p if p.is_dir() else p.parent)
        targets = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for t in targets:
            if any(part in _SKIP_DIRS for part in t.parts):
                continue
            try:
                rel = str(t.relative_to(base))
            except ValueError:
                rel = str(t)
            try:
                out.append(SourceFile(t, rel, t.read_text()))
            except SyntaxError as e:
                raise SystemExit(f"reprolint: cannot parse {t}: {e}")
    return out


def lint_paths(paths: Sequence, *, rules: Optional[Sequence[str]] = None,
               root: Optional[Path] = None) -> List[Finding]:
    """Run the (selected) rules over ``paths``; returns UNSUPPRESSED
    findings sorted by (path, line, rule).  Baseline filtering is the
    caller's job (``lint.py``)."""
    _ensure_rules_loaded()
    project = Project(collect_files(paths, root=root))
    selected = list(rules) if rules else sorted(RULES)
    unknown = [r for r in selected if r not in RULES]
    if unknown:
        raise SystemExit(f"reprolint: unknown rule(s) {unknown} "
                         f"(have: {sorted(RULES)})")
    findings: List[Finding] = []
    for rid in selected:
        for f in RULES[rid](project):
            sf = project.by_rel.get(f.path)
            if sf is not None and sf.is_suppressed(f.line, f.rule):
                continue
            findings.append(f)
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule))


# --------------------------------------------------------------------------
# baseline
# --------------------------------------------------------------------------
def load_baseline(path) -> set:
    p = Path(path)
    if not p.exists():
        return set()
    keys = set()
    for line in p.read_text().splitlines():
        line = line.strip()
        if line and not line.startswith("#"):
            keys.add(line)
    return keys


def write_baseline(path, findings: Sequence[Finding]) -> None:
    header = ("# reprolint baseline: grandfathered findings "
              "(regenerate with --write-baseline).\n"
              "# One `relpath::RULE::message` per line; delete a line once "
              "its finding is fixed.\n")
    body = "".join(f.key + "\n" for f in findings)
    Path(path).write_text(header + body)
