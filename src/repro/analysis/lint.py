"""reprolint CLI.

Usage::

    PYTHONPATH=src python -m repro.analysis.lint src/
    PYTHONPATH=src python -m repro.analysis.lint src/ --rules R2,R3
    PYTHONPATH=src python -m repro.analysis.lint src/ --write-baseline
    PYTHONPATH=src python -m repro.analysis.lint --list-rules

Exit status 0 iff every finding is suppressed inline or present in the
committed baseline (``src/repro/analysis/baseline.txt`` by default);
otherwise each fresh finding is printed as ``file:line RULE message``
and the exit status is 1.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.analysis import core

_DEFAULT_BASELINE = Path(__file__).parent / "baseline.txt"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="Static invariant checks for this repo "
                    "(jit purity, donation, host syncs, locks, pytrees, "
                    "slot protocol, retrace/compile-cache audit, kernel "
                    "bounds proofs, boundary-protocol model check)")
    ap.add_argument("paths", nargs="*", help="files or directories to lint")
    ap.add_argument("--rules", default=None,
                    help="comma-separated subset, e.g. R1,R3")
    ap.add_argument("--baseline", default=str(_DEFAULT_BASELINE),
                    help="baseline file of grandfathered finding keys")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline (report everything)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write current findings to the baseline and exit 0")
    ap.add_argument("--list-rules", action="store_true")
    ap.add_argument("--root", default=None,
                    help="anchor for relative paths in findings/baseline")
    ap.add_argument("--format", choices=("text", "github"), default="text",
                    help="'github' additionally emits workflow-command "
                         "annotations (::error file=...) so findings show "
                         "inline on the PR diff")
    args = ap.parse_args(argv)

    if args.list_rules:
        core._ensure_rules_loaded()
        for rid in sorted(core.RULE_DOC):
            print(f"{rid}  {core.RULE_DOC[rid]}")
        return 0
    if not args.paths:
        ap.error("no paths given (or use --list-rules)")

    rules = [r.strip() for r in args.rules.split(",")] if args.rules \
        else None
    root = Path(args.root) if args.root else None
    findings = core.lint_paths(args.paths, rules=rules, root=root)

    if args.write_baseline:
        core.write_baseline(args.baseline, findings)
        print(f"reprolint: wrote {len(findings)} finding key(s) to "
              f"{args.baseline}")
        return 0

    baseline = set() if args.no_baseline else \
        core.load_baseline(args.baseline)
    fresh = [f for f in findings if f.key not in baseline]
    for f in fresh:
        print(f.render())
        if args.format == "github":
            # GitHub workflow command: annotates the offending line on
            # the PR.  Message newlines/percents must be URL-escaped per
            # the workflow-command spec.
            msg = f"{f.rule}: {f.message}".replace("%", "%25") \
                .replace("\r", "%0D").replace("\n", "%0A")
            print(f"::error file={f.path},line={f.line},"
                  f"title=reprolint {f.rule}::{msg}")
    n_base = len(findings) - len(fresh)
    if fresh:
        print(f"reprolint: {len(fresh)} finding(s)"
              + (f" ({n_base} baselined)" if n_base else ""),
              file=sys.stderr)
        return 1
    stale = baseline - {f.key for f in findings}
    if stale:
        print(f"reprolint: clean; {len(stale)} stale baseline entr"
              f"{'y' if len(stale) == 1 else 'ies'} can be deleted",
              file=sys.stderr)
    print(f"reprolint: clean"
          + (f" ({n_base} baselined finding(s))" if n_base else ""))
    return 0


if __name__ == "__main__":
    sys.exit(main())
