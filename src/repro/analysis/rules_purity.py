"""R1 — jit-purity: no host side effects inside traced code.

Everything reachable from a jit root (``jax.jit`` targets, ``lax.scan``
/ ``while_loop`` / ``fori_loop`` bodies, ``checkpoint`` / ``grad`` /
``vmap`` operands — see ``callgraph``) runs under a tracer: host clocks
read trace time not step time, ``print`` fires once at trace then never
again, host ``random`` freezes one sample into the compiled graph,
``np.*`` on a tracer forces a device sync (or a trace error), and
``int()``/``float()``/``bool()`` on a traced argument raises a
``ConcretizationTypeError`` only on the unlucky path that executes it.
Mutable default arguments are captured at trace time and shared across
every compiled call.
"""
from __future__ import annotations

import ast
from typing import List

from repro.analysis import callgraph
from repro.analysis.core import Finding, Project, register_rule
from repro.analysis.callgraph import dotted

# numpy attributes that are legal inside traced code: dtype objects and
# scalar-type constructors used as `jnp.zeros(..., np.int32)` arguments
_NP_DTYPE_OK = {
    "int8", "int16", "int32", "int64", "uint8", "uint16", "uint32",
    "uint64", "float16", "float32", "float64", "bfloat16", "bool_",
    "complex64", "complex128", "integer", "floating", "dtype", "ndarray",
    "generic", "number", "inf", "nan", "newaxis", "pi", "e",
}


@register_rule(
    "R1",
    "jit-purity: no time.*/print/random/np.*/scalar coercions/mutable "
    "defaults inside functions reachable from jit or lax.scan roots")
def rule_jit_purity(project: Project) -> List[Finding]:
    idx = callgraph.get_index(project)
    out = {}

    def add(fi, line, msg):
        out[(fi.file.rel, line, msg)] = Finding(
            path=fi.file.rel, line=line, rule="R1", message=msg)

    for fi in idx.reached_from_jit():
        mod = idx._module_of(fi)
        imports = mod.imports if mod is not None else {}
        qual = fi.qualname
        args = fi.node.args
        for dflt in list(args.defaults) + \
                [d for d in args.kw_defaults if d is not None]:
            if isinstance(dflt, (ast.List, ast.Dict, ast.Set)):
                add(fi, dflt.lineno,
                    f"mutable default argument in jit-reachable `{qual}` "
                    f"is captured at trace time and shared across calls")
        params = set(fi.params)
        body = [fi.node.body] if isinstance(fi.node, ast.Lambda) \
            else list(fi.node.body)
        for stmt in body:
            for node in ast.walk(stmt):
                if not isinstance(node, ast.Call):
                    continue
                d = dotted(node.func)
                if d is not None:
                    base = d.split(".")[0]
                    target = imports.get(base, base)
                    if d == "print":
                        add(fi, node.lineno,
                            f"print() inside jit-reachable `{qual}` fires "
                            f"at trace time only (use jax.debug.print)")
                    elif target == "time" or target.startswith("time."):
                        add(fi, node.lineno,
                            f"host clock `{d}` inside jit-reachable "
                            f"`{qual}` reads trace time, not step time")
                    elif (target == "random"
                          or target.startswith("random.")
                          or (target.startswith("numpy")
                              and ".random" in d)):
                        add(fi, node.lineno,
                            f"host RNG `{d}` inside jit-reachable `{qual}` "
                            f"freezes one sample into the compiled graph "
                            f"(use jax.random)")
                    elif target.startswith("numpy"):
                        if d.split(".")[-1] not in _NP_DTYPE_OK:
                            add(fi, node.lineno,
                                f"numpy host op `{d}` inside jit-reachable "
                                f"`{qual}` breaks tracing / forces a sync "
                                f"(use jnp)")
                    if d.endswith(".item"):
                        add(fi, node.lineno,
                            f"`.item()` inside jit-reachable `{qual}` "
                            f"concretizes a tracer")
                if (isinstance(node.func, ast.Name)
                        and node.func.id in ("int", "float", "bool")
                        and len(node.args) == 1
                        and isinstance(node.args[0], ast.Name)
                        and node.args[0].id in params):
                    add(fi, node.lineno,
                        f"`{node.func.id}({node.args[0].id})` coerces a "
                        f"traced argument of `{qual}` to a host scalar")
    return list(out.values())
