"""R7 — jit retrace & compile-cache audit (static half).

jax's compile cache is keyed on ``(jitted function object, static arg
values, abstract values of traced args)``.  Three whole classes of bug
defeat it silently — the program stays correct and 100x slower:

* **construction in a hot path**: ``jax.jit(f)`` inside a per-step /
  per-boundary method (or any loop) builds a *fresh* function object
  every call, so the cache never hits.  Memoised construction —
  ``self._memo[key] = jax.jit(...)`` — is the sanctioned pattern and is
  exempt.  Hot scope = anything reachable from a ``sched_*`` slot
  method, ``generate``, ``boundary`` or ``time_step``.
* **fresh / unhashable statics**: a dict/list/set literal passed at a
  ``static_argnums``/``static_argnames`` position raises at call time;
  a lambda or comprehension is hashed *by identity*, so a fresh one per
  call is a guaranteed miss.  Tuples are checked element-wise (a tuple
  of lambdas is as bad as a lambda).
* **scalar-vs-array skew**: the same parameter of one jitted function
  fed a Python scalar at one call site and a traced array at another
  compiles *two* cache entries and retraces on every path switch.  Call
  sites are grouped per (jitted callable, arg position) — including
  one hop of forwarding through a plain method that passes its own
  parameter straight into the jit (``sched_step`` style), with
  ``obj.sched_x(...)`` calls linked to the unique concrete class that
  implements the slot.

The dynamic counterpart (``python -m repro.analysis.tracecount``) pins
the *actual* compile counts of a smoke run against
``compile_budget.json``; this rule catches the same bugs without
running jax at all.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis import callgraph
from repro.analysis.callgraph import ClassInfo, FuncInfo, ModuleInfo, dotted
from repro.analysis.core import Finding, Project, register_rule

# names whose bodies run once per decode step / scheduler boundary: the
# roots of the "hot" closure for the construction check
_HOT_NAMES = {"generate", "boundary", "time_step"}

# numpy-ish constructors whose result traces as an array aval
_ARRAY_FNS = {"asarray", "array", "zeros", "ones", "full", "arange",
              "zeros_like", "ones_like", "full_like", "where",
              "broadcast_to", "minimum", "maximum", "concatenate",
              "stack"}
_ARRAY_PREFIXES = {"jnp", "np", "numpy", "jax.numpy"}
_SCALAR_CASTS = {"int", "float", "bool"}


def _name(fi: FuncInfo) -> str:
    return getattr(fi.node, "name", fi.qualname)


def _own_nodes(fn_node):
    """Nodes in a function's own body, not descending into nested
    def/lambda bodies (those execute in their own scope, later)."""
    body = [fn_node.body] if isinstance(fn_node, ast.Lambda) \
        else list(fn_node.body)
    stack = list(body)
    while stack:
        n = stack.pop()
        yield n
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                          ast.Lambda)):
            continue
        stack.extend(ast.iter_child_nodes(n))


def _all_funcs(idx):
    seen: Set[int] = set()

    def rec(fi):
        if id(fi.node) in seen:
            return
        seen.add(id(fi.node))
        yield fi
        for sub in fi.locals.values():
            yield from rec(sub)

    for mod in idx.modules.values():
        for fi in mod.funcs.values():
            yield from rec(fi)
        for ci in mod.classes.values():
            for fi in ci.methods.values():
                yield from rec(fi)


def _is_stub(fi: FuncInfo) -> bool:
    """Protocol/ABC stub: body of docstring / Ellipsis / pass / raise."""
    for stmt in fi.node.body:
        if isinstance(stmt, ast.Expr) and \
                isinstance(stmt.value, ast.Constant):
            continue
        if isinstance(stmt, (ast.Pass, ast.Raise)):
            continue
        return False
    return True


def _memo_exempt(tree) -> Set[int]:
    """ids of Call nodes whose value lands in a Subscript target —
    ``self._memo[key] = jax.jit(...)`` memoised construction."""
    out: Set[int] = set()
    for n in ast.walk(tree):
        if isinstance(n, ast.Assign) and any(
                isinstance(t, ast.Subscript) for t in n.targets):
            for c in ast.walk(n.value):
                if isinstance(c, ast.Call):
                    out.add(id(c))
    return out


# --------------------------------------------------------------------------
# statics parsing
# --------------------------------------------------------------------------
def _static_spec(call_or_dec) -> Tuple[Set[int], Set[str]]:
    nums: Set[int] = set()
    names: Set[str] = set()
    for k in call_or_dec.keywords:
        if k.arg == "static_argnums":
            v = k.value
            if isinstance(v, ast.Constant) and isinstance(v.value, int):
                nums.add(v.value)
            elif isinstance(v, (ast.Tuple, ast.List)):
                nums |= {e.value for e in v.elts
                         if isinstance(e, ast.Constant)
                         and isinstance(e.value, int)}
        elif k.arg == "static_argnames":
            v = k.value
            if isinstance(v, ast.Constant) and isinstance(v.value, str):
                names.add(v.value)
            elif isinstance(v, (ast.Tuple, ast.List)):
                names |= {e.value for e in v.elts
                          if isinstance(e, ast.Constant)
                          and isinstance(e.value, str)}
    return nums, names


def _fresh_desc(node) -> Optional[Tuple[str, str]]:
    """(description, severity-phrase) when ``node`` is a fresh/unhashable
    static value; recurses through tuple literals."""
    if isinstance(node, ast.Lambda):
        return ("lambda", "hashed by identity, a fresh object per call "
                "is a guaranteed compile-cache miss")
    if isinstance(node, ast.Dict):
        return ("dict literal", "unhashable — jit raises at call time")
    if isinstance(node, (ast.List, ast.Set)):
        kind = "list" if isinstance(node, ast.List) else "set"
        return (f"{kind} literal", "unhashable — jit raises at call time")
    if isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                         ast.GeneratorExp)):
        return ("comprehension", "fresh (and for list/set/dict "
                "unhashable) object every call")
    if isinstance(node, ast.Call) and dotted(node.func) in \
            ("dict", "list", "set"):
        return (f"{dotted(node.func)}() call",
                "unhashable — jit raises at call time")
    if isinstance(node, ast.Tuple):
        for e in node.elts:
            inner = _fresh_desc(e)
            if inner is not None:
                return (f"tuple containing a {inner[0]}", inner[1])
    return None


# --------------------------------------------------------------------------
# jit-callee registry (for statics + scalar/array grouping)
# --------------------------------------------------------------------------
class _JitCallee:
    """One jitted callable as seen from call sites."""

    def __init__(self, display: str, params: Optional[List[str]],
                 nums: Set[int], names: Set[str]):
        self.display = display
        self.params = params
        self.nums = nums
        self.names = names


def _jit_target_params(idx, call: ast.Call, scope, f) -> Optional[List[str]]:
    d = dotted(call.func)
    i = 1 if d in ("partial", "functools.partial") else 0
    tgt = idx._callable_arg(call, i, scope, f)
    if tgt is None:
        return None
    return tgt.params


def _build_registry(idx) -> Tuple[Dict, Dict, Dict, Dict]:
    """Returns (by_def, by_attr, by_factory, by_modname):

    * by_def:     id(FunctionDef) -> _JitCallee   (decorated defs)
    * by_attr:    (id(ClassInfo)|id(ModuleInfo), attr) -> _JitCallee
    * by_factory: (id(ClassInfo), method) -> _JitCallee
      (method whose body memoises ``self._m[k] = jax.jit(...)`` —
      called as ``self.method(key)(args...)``)
    """
    by_def: Dict[int, _JitCallee] = {}
    by_attr: Dict[Tuple[int, str], _JitCallee] = {}
    by_factory: Dict[Tuple[int, str], _JitCallee] = {}

    for fi in _all_funcs(idx):
        node = fi.node
        if isinstance(node, ast.Lambda):
            continue
        for dec in idx._jit_decorators(node):
            nums, names = (_static_spec(dec)
                           if isinstance(dec, ast.Call) else (set(), set()))
            by_def[id(node)] = _JitCallee(fi.qualname, fi.params,
                                          nums, names)

    for mod in idx.modules.values():
        # module-level `name = jax.jit(...)`
        for stmt in mod.file.tree.body:
            if isinstance(stmt, ast.Assign) and \
                    isinstance(stmt.value, ast.Call) and \
                    idx._trace_entry_name(stmt.value, mod) == "jit":
                for t in stmt.targets:
                    if isinstance(t, ast.Name):
                        nums, names = _static_spec(stmt.value)
                        by_attr[(id(mod), t.id)] = _JitCallee(
                            f"{mod.name}.{t.id}",
                            _jit_target_params(idx, stmt.value, mod,
                                               mod.file),
                            nums, names)
        for ci in mod.classes.values():
            for m in ci.methods.values():
                for n in ast.walk(m.node):
                    if not (isinstance(n, ast.Assign) and
                            isinstance(n.value, ast.Call) and
                            idx._trace_entry_name(n.value, m) == "jit"):
                        continue
                    nums, names = _static_spec(n.value)
                    params = _jit_target_params(idx, n.value, m, m.file)
                    for t in n.targets:
                        # self._x = jax.jit(...)
                        if isinstance(t, ast.Attribute) and \
                                isinstance(t.value, ast.Name) and \
                                t.value.id == "self":
                            by_attr[(id(ci), t.attr)] = _JitCallee(
                                f"{ci.name}.{t.attr}", params, nums,
                                names)
                        # self._memo[key] = jax.jit(...): `m` is a
                        # factory — call sites look like self.m(k)(...)
                        elif isinstance(t, ast.Subscript):
                            by_factory[(id(ci), m.node.name)] = \
                                _JitCallee(f"{ci.name}.{m.node.name}",
                                           params, nums, names)
    return by_def, by_attr, by_factory


def _callee_at(idx, call: ast.Call, fi: FuncInfo, regs
               ) -> Optional[Tuple[_JitCallee, int]]:
    """(callee, self_offset) when ``call`` invokes a jitted callable.
    ``self_offset`` maps call arg position i -> callee param i+offset."""
    by_def, by_attr, by_factory = regs
    fn = call.func
    # self._chunk_fn(K)(args...) — factory pattern
    if isinstance(fn, ast.Call) and isinstance(fn.func, ast.Attribute) \
            and isinstance(fn.func.value, ast.Name) \
            and fn.func.value.id == "self" and fi.cls is not None:
        rec = by_factory.get((id(fi.cls), fn.func.attr))
        if rec is not None:
            return rec, 0
    # jax.jit(f, ...)(args...) — immediate invocation
    if isinstance(fn, ast.Call) and \
            idx._trace_entry_name(fn, fi) == "jit":
        nums, names = _static_spec(fn)
        params = _jit_target_params(idx, fn, fi, fi.file)
        return _JitCallee(dotted(fn.args[0]) if fn.args else "<jit>",
                          params, nums, names), 0
    # self._x(args...) — attribute-bound jit
    if isinstance(fn, ast.Attribute) and isinstance(fn.value, ast.Name) \
            and fn.value.id == "self" and fi.cls is not None:
        rec = by_attr.get((id(fi.cls), fn.attr))
        if rec is not None:
            return rec, 0
    # name(args...) — module-bound jit or decorated def
    if isinstance(fn, ast.Name):
        mod = idx._module_of(fi)
        if mod is not None:
            rec = by_attr.get((id(mod), fn.id))
            if rec is not None:
                return rec, 0
    resolved = idx.resolve_call(call, fi)
    if resolved is not None and id(resolved.node) in by_def:
        rec = by_def[id(resolved.node)]
        offset = 1 if (resolved.cls is not None and
                       resolved.params[:1] == ["self"] and
                       isinstance(fn, ast.Attribute)) else 0
        return rec, offset
    return None


# --------------------------------------------------------------------------
# scalar-vs-array classification
# --------------------------------------------------------------------------
def _classify(idx, expr, fi, depth=0, seen=None) -> Optional[str]:
    """'scalar' | 'array' | None (unknown) for the traced aval of expr."""
    if depth > 5:
        return None
    seen = seen if seen is not None else set()
    if id(expr) in seen:
        return None
    seen.add(id(expr))

    if isinstance(expr, ast.Constant):
        if isinstance(expr.value, bool) or \
                isinstance(expr.value, (int, float)):
            return "scalar"
        return None
    if isinstance(expr, ast.UnaryOp) and isinstance(expr.op, ast.USub):
        return _classify(idx, expr.operand, fi, depth + 1, seen)
    if isinstance(expr, ast.Call):
        d = dotted(expr.func)
        if d in _SCALAR_CASTS:
            return "scalar"
        if d is not None:
            parts = d.split(".")
            if parts[-1] in _ARRAY_FNS and (
                    ".".join(parts[:-1]) in _ARRAY_PREFIXES):
                return "array"
        callee = idx.resolve_call(expr, fi) if isinstance(fi, FuncInfo) \
            else None
        if callee is not None and not isinstance(callee.node, ast.Lambda):
            kinds = set()
            for n in ast.walk(callee.node):
                if isinstance(n, ast.Return) and n.value is not None:
                    kinds.add(_classify(idx, n.value, callee, depth + 1,
                                        seen))
            if len(kinds) == 1:
                return kinds.pop()
        return None
    if isinstance(expr, ast.Name) and isinstance(fi, FuncInfo):
        if expr.id in fi.params:
            return None                      # forwarding handles params
        kinds = set()
        for n in _own_nodes(fi.node):
            if not isinstance(n, ast.Assign):
                continue
            for t in n.targets:
                if isinstance(t, ast.Name) and t.id == expr.id:
                    kinds.add(_classify(idx, n.value, fi, depth + 1,
                                        seen))
                elif isinstance(t, ast.Tuple) and \
                        isinstance(n.value, ast.Tuple) and \
                        len(t.elts) == len(n.value.elts):
                    for te, ve in zip(t.elts, n.value.elts):
                        if isinstance(te, ast.Name) and te.id == expr.id:
                            kinds.add(_classify(idx, ve, fi, depth + 1,
                                                seen))
        if len(kinds) == 1 and None not in kinds:
            return kinds.pop()
        return None
    if isinstance(expr, ast.Attribute) and \
            isinstance(expr.value, ast.Name) and expr.value.id == "self" \
            and isinstance(fi, FuncInfo) and fi.cls is not None:
        kinds = set()
        for m in fi.cls.methods.values():
            for n in ast.walk(m.node):
                if not isinstance(n, ast.Assign):
                    continue
                for t in n.targets:
                    if isinstance(t, ast.Attribute) and \
                            isinstance(t.value, ast.Name) and \
                            t.value.id == "self" and t.attr == expr.attr:
                        kinds.add(_classify(idx, n.value, m, depth + 1,
                                            seen))
        if len(kinds) == 1 and None not in kinds:
            return kinds.pop()
        return None
    return None


def _unique_slot_method(idx, attr: str) -> Optional[FuncInfo]:
    """For ``obj.sched_x(...)`` on a dynamic object: the unique concrete
    (non-Protocol, non-stub) class method implementing the slot."""
    if not attr.startswith("sched_"):
        return None
    hits = []
    seen_cls: Set[int] = set()
    for mod in idx.modules.values():
        for ci in mod.classes.values():
            if any(b.split(".")[-1] == "Protocol" for b in ci.base_names):
                continue
            m = ci.methods.get(attr)
            if m is not None and not _is_stub(m) and \
                    id(m.node) not in seen_cls:
                seen_cls.add(id(m.node))
                hits.append(m)
    return hits[0] if len(hits) == 1 else None


# --------------------------------------------------------------------------
# the rule
# --------------------------------------------------------------------------
@register_rule(
    "R7",
    "jit retrace audit: fresh/unhashable static args, Python-scalar vs "
    "array skew across call sites of one jit, and jit construction in "
    "hot paths or loops without memoisation")
def rule_retrace(project: Project) -> List[Finding]:
    idx = callgraph.get_index(project)
    out: List[Finding] = []
    flagged: Set[int] = set()           # Call ids already reported

    def add(f, line, msg):
        out.append(Finding(path=f.rel, line=line, rule="R7", message=msg))

    # ---- A. construction in hot paths / loops ---------------------------
    exempt: Dict[int, Set[int]] = {}    # per-file memoised-construction ids
    for f in project.files:
        exempt[id(f)] = _memo_exempt(f.tree)

    roots = [fi for fi in _all_funcs(idx)
             if not isinstance(fi.node, ast.Lambda)
             and (fi.node.name in _HOT_NAMES
                  or fi.node.name.startswith("sched_"))
             and not _is_stub(fi)]
    hot: Dict[int, FuncInfo] = {}
    work = list(roots)
    while work:
        fi = work.pop()
        if id(fi.node) in hot:
            continue
        hot[id(fi.node)] = fi
        for n in _own_nodes(fi.node):
            if isinstance(n, ast.Call):
                callee = idx.resolve_call(n, fi)
                if callee is not None and \
                        not isinstance(callee.node, ast.Lambda):
                    work.append(callee)

    for fi in hot.values():
        for n in _own_nodes(fi.node):
            if isinstance(n, ast.Call) and \
                    idx._trace_entry_name(n, fi) == "jit" and \
                    id(n) not in exempt[id(fi.file)] and \
                    id(n) not in flagged:
                flagged.add(id(n))
                add(fi.file, n.lineno,
                    f"jax.jit constructed inside hot path "
                    f"`{fi.qualname}` without memoisation — a fresh jit "
                    f"object per call never hits the compile cache; "
                    f"build it once (e.g. in __init__) or memoise it as "
                    f"`self._memo[key] = jax.jit(...)`")

    for f in project.files:
        mod = idx.modules.get(callgraph._module_name(f.rel))
        for loop in ast.walk(f.tree):
            if not isinstance(loop, (ast.For, ast.While)):
                continue
            stack = list(loop.body) + list(loop.orelse)
            while stack:
                n = stack.pop()
                if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
                    continue
                if isinstance(n, ast.Call) and \
                        idx._trace_entry_name(n, mod) == "jit" and \
                        id(n) not in exempt[id(f)] and \
                        id(n) not in flagged:
                    flagged.add(id(n))
                    add(f, n.lineno,
                        "jax.jit constructed inside a loop without "
                        "memoisation — every iteration builds a fresh "
                        "jit object and recompiles; hoist it out of the "
                        "loop or memoise per static key")
                stack.extend(ast.iter_child_nodes(n))

    # ---- B + C. call-site checks over the jit-callee registry ----------
    regs = _build_registry(idx)
    by_def, by_attr, by_factory = regs

    # (display, pos) -> list of (kind, file, line, SourceFile)
    groups: Dict[Tuple[str, int], List] = {}
    # (id(FuncInfo.node), param_index) -> (display, jit_pos, callee)
    forwards: Dict[Tuple[int, int], Tuple[str, int, _JitCallee]] = {}
    funcs = list(_all_funcs(idx))

    for fi in funcs:
        for call in _own_nodes(fi.node):
            if not isinstance(call, ast.Call):
                continue
            hit = _callee_at(idx, call, fi, regs)
            if hit is None:
                continue
            rec, offset = hit
            # B: fresh/unhashable statics at this call site
            for i, a in enumerate(call.args):
                pnum = i + offset
                pname = (rec.params[pnum]
                         if rec.params and pnum < len(rec.params) else None)
                if pnum in rec.nums or (pname in rec.names):
                    fd = _fresh_desc(a)
                    if fd is not None:
                        add(fi.file, call.lineno,
                            f"call to jitted `{rec.display}` passes a "
                            f"{fd[0]} as static arg "
                            f"{pname or pnum} — {fd[1]}")
            for kw in call.keywords:
                if kw.arg in rec.names or (
                        rec.params and kw.arg in rec.params and
                        rec.params.index(kw.arg) in rec.nums):
                    fd = _fresh_desc(kw.value)
                    if fd is not None:
                        add(fi.file, call.lineno,
                            f"call to jitted `{rec.display}` passes a "
                            f"{fd[0]} as static arg {kw.arg} — {fd[1]}")
            # C: classify traced args / register forwards
            if rec.params is None:
                continue
            for i, a in enumerate(call.args):
                pnum = i + offset
                if pnum in rec.nums or pnum >= len(rec.params):
                    continue
                if rec.params[pnum] in rec.names:
                    continue
                key = (rec.display, pnum)
                kind = _classify(idx, a, fi)
                if kind is not None:
                    groups.setdefault(key, []).append(
                        (kind, fi.file, call.lineno, rec))
                elif isinstance(a, ast.Name) and a.id in fi.params:
                    forwards[(id(fi.node), fi.params.index(a.id))] = \
                        (rec.display, pnum, rec)

    # one forwarding hop: call sites of functions that pass a parameter
    # straight into a jit contribute their own arg classification
    if forwards:
        for fi in funcs:
            for call in _own_nodes(fi.node):
                if not isinstance(call, ast.Call):
                    continue
                target = idx.resolve_call(call, fi)
                if target is None and \
                        isinstance(call.func, ast.Attribute):
                    target = _unique_slot_method(idx, call.func.attr)
                if target is None:
                    continue
                offset = 1 if (target.cls is not None and
                               target.params[:1] == ["self"] and
                               isinstance(call.func, ast.Attribute)) \
                    else 0
                for (fnid, pidx), (disp, jpos, rec) in forwards.items():
                    if fnid != id(target.node):
                        continue
                    ci = pidx - offset
                    if 0 <= ci < len(call.args):
                        kind = _classify(idx, call.args[ci], fi)
                        if kind is not None:
                            groups.setdefault((disp, jpos), []).append(
                                (kind, fi.file, call.lineno, rec))

    for (disp, pos), sites in sorted(groups.items()):
        kinds = {k for k, *_ in sites}
        if "scalar" not in kinds or "array" not in kinds:
            continue
        scalar_sites = sorted([s for s in sites if s[0] == "scalar"],
                              key=lambda s: (s[1].rel, s[2]))
        array_sites = sorted([s for s in sites if s[0] == "array"],
                             key=lambda s: (s[1].rel, s[2]))
        _, f, line, rec = scalar_sites[0]
        pname = (rec.params[pos] if rec.params and pos < len(rec.params)
                 else str(pos))
        add(f, line,
            f"argument `{pname}` of jitted `{disp}` is a Python scalar "
            f"here but a traced array at {array_sites[0][1].rel} — the "
            f"two avals key separate compile-cache entries, so each "
            f"path switch retraces; coerce one side (e.g. jnp.asarray) "
            f"so every call site shares one compilation")
    return out
