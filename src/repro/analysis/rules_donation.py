"""R2 — donation discipline for state-carrying jits.

The engine's whole residency story rests on the paged pool being updated
in place: every jit that threads a cache/pool/state carry must declare
``donate_argnums`` for it, or XLA double-buffers the carry (PR 3
measured the pool at 2x memory without donation).  And once donated, the
buffer is dead — reading the donated name after the jitted call in the
enclosing scope is a use-after-free that jax only reports at runtime.

Detection: for every ``jax.jit`` site with a statically-resolvable
target, an argument is *state-like* if its parameter name looks like a
carry (``state``/``st``/``cache``/``pool``/``opt``/``*_state``/...), or
if it is forwarded one call deep into a parameter with such a name
(lambda wrappers: ``jax.jit(lambda p, o, b: train_step(.., p, o, b))``).
``params`` deliberately does NOT match — inference jits thread model
parameters across calls and must not donate them.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Tuple

from repro.analysis import callgraph
from repro.analysis.core import Finding, Project, register_rule
from repro.analysis.callgraph import FuncInfo, dotted

_STATE_EXACT = {"state", "st", "cache", "carry", "pool", "opt",
                "opt_state", "kv", "kv_cache", "mem", "memory", "buf"}


def _statelike(name: str) -> bool:
    return name in _STATE_EXACT or \
        name.endswith(("_state", "_cache", "_pool", "_carry"))


def _donate_names(keywords) -> Tuple[str, ...]:
    for k in keywords:
        if k.arg == "donate_argnames":
            v = k.value
            if isinstance(v, ast.Constant) and isinstance(v.value, str):
                return (v.value,)
            if isinstance(v, (ast.Tuple, ast.List)):
                return tuple(e.value for e in v.elts
                             if isinstance(e, ast.Constant)
                             and isinstance(e.value, str))
    return ()


def _stateful_args(idx, target: FuncInfo) -> Dict[int, str]:
    """index -> reason, for every state-like parameter of ``target``."""
    params = [p for p in target.params if p not in ("self", "cls")]
    stateful: Dict[int, str] = {}
    for i, p in enumerate(params):
        if _statelike(p):
            stateful[i] = f"`{p}`"
    # one hop: a param forwarded (by position or keyword) into a callee's
    # state-like parameter is itself the carry
    body = [target.node.body] if isinstance(target.node, ast.Lambda) \
        else list(target.node.body)
    pos_of = {p: i for i, p in enumerate(params)}
    for stmt in body:
        for node in ast.walk(stmt):
            if not isinstance(node, ast.Call):
                continue
            callee = idx.resolve_call(node, target)
            if callee is None:
                continue
            cparams = [p for p in callee.params if p not in ("self", "cls")]
            for j, arg in enumerate(node.args):
                if isinstance(arg, ast.Name) and arg.id in pos_of \
                        and j < len(cparams) and _statelike(cparams[j]):
                    stateful.setdefault(
                        pos_of[arg.id],
                        f"`{arg.id}` (forwarded to "
                        f"`{callee.qualname}({cparams[j]})`)")
            for kw in node.keywords:
                if kw.arg and isinstance(kw.value, ast.Name) \
                        and kw.value.id in pos_of and _statelike(kw.arg):
                    stateful.setdefault(
                        pos_of[kw.value.id],
                        f"`{kw.value.id}` (forwarded to "
                        f"`{callee.qualname}({kw.arg})`)")
    return stateful


@register_rule(
    "R2",
    "donation discipline: state-carrying jits declare donate_argnums; "
    "donated names are never read after the jitted call")
def rule_donation(project: Project) -> List[Finding]:
    idx = callgraph.get_index(project)
    out: List[Finding] = []
    seen = set()

    def add(rel, line, msg):
        if (rel, line, msg) not in seen:
            seen.add((rel, line, msg))
            out.append(Finding(path=rel, line=line, rule="R2", message=msg))

    for site in idx.jit_sites:
        if site.target is None:
            continue
        stateful = _stateful_args(idx, site.target)
        if not stateful:
            continue
        tname = site.target.qualname
        if not site.has_donate:
            names = ", ".join(stateful[i] for i in sorted(stateful))
            add(site.file.rel, site.line,
                f"jit of `{tname}` threads state-like argument(s) {names} "
                f"but declares no donate_argnums — the carry is "
                f"double-buffered instead of updated in place")
            continue
        donate_names = () if site.call is None else \
            _donate_names(site.call.keywords)
        params = [p for p in site.target.params if p not in ("self", "cls")]
        for i in sorted(stateful):
            if i not in site.donate and params[i] not in donate_names \
                    and (site.donate or donate_names):
                add(site.file.rel, site.line,
                    f"state-like argument {stateful[i]} (index {i}) of "
                    f"jitted `{tname}` is missing from donate_argnums"
                    f"={site.donate}")

    # ---- use-after-donate ------------------------------------------------
    for f in project.files:
        site_by_call = {id(s.call): s for s in idx.jit_sites
                        if s.call is not None and s.file is f}
        for node in ast.walk(f.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            _check_use_after_donate(f, node, site_by_call, add)
        # self._x = jax.jit(...) in __init__, called from other methods
        for cnode in ast.walk(f.tree):
            if not isinstance(cnode, ast.ClassDef):
                continue
            attr_donate: Dict[str, Tuple[int, ...]] = {}
            for sub in ast.walk(cnode):
                if isinstance(sub, ast.Assign) and id(sub.value) in \
                        site_by_call and len(sub.targets) == 1:
                    t = sub.targets[0]
                    if isinstance(t, ast.Attribute) and \
                            isinstance(t.value, ast.Name) and \
                            t.value.id == "self":
                        s = site_by_call[id(sub.value)]
                        if s.donate:
                            attr_donate[t.attr] = s.donate
            if not attr_donate:
                continue
            for m in cnode.body:
                if isinstance(m, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    _check_attr_use_after_donate(f, m, attr_donate, add)
    return out


def _name_lines(fn_node, name):
    loads, stores = [], []
    for n in ast.walk(fn_node):
        if isinstance(n, ast.Name) and n.id == name:
            (loads if isinstance(n.ctx, ast.Load) else stores).append(
                n.lineno)
    return sorted(loads), sorted(stores)


def _flag_reads_after(f, fn_node, call, donated_args, add, label):
    end = getattr(call, "end_lineno", call.lineno)
    for name in donated_args:
        loads, stores = _name_lines(fn_node, name)
        for load in loads:
            if load <= end:
                continue
            if any(call.lineno <= s <= load for s in stores):
                break           # rebound before (or at) this read: fine
            add(f.rel, load,
                f"`{name}` is read after being donated to {label} — "
                f"donated buffers are invalidated by the call")
            break               # one finding per donated name is enough


def _in_return(fn_node) -> set:
    """ids of every node nested inside a Return statement: a donating
    call whose value is immediately returned leaves the scope — later
    reads on sibling branches are not reads-after-donate."""
    out = set()
    for node in ast.walk(fn_node):
        if isinstance(node, ast.Return) and node.value is not None:
            for sub in ast.walk(node.value):
                out.add(id(sub))
    return out


def _check_use_after_donate(f, fn_node, site_by_call, add):
    jitted_vars: Dict[str, Tuple[int, ...]] = {}
    for sub in ast.walk(fn_node):
        if isinstance(sub, ast.Assign) and id(sub.value) in site_by_call \
                and len(sub.targets) == 1 and \
                isinstance(sub.targets[0], ast.Name):
            s = site_by_call[id(sub.value)]
            if s.donate:
                jitted_vars[sub.targets[0].id] = s.donate
    if not jitted_vars:
        return
    returned = _in_return(fn_node)
    for sub in ast.walk(fn_node):
        if id(sub) in returned:
            continue
        if isinstance(sub, ast.Call) and isinstance(sub.func, ast.Name) \
                and sub.func.id in jitted_vars:
            donate = jitted_vars[sub.func.id]
            donated_args = [a.id for i, a in enumerate(sub.args)
                            if i in donate and isinstance(a, ast.Name)]
            _flag_reads_after(f, fn_node, sub, donated_args, add,
                              f"jitted `{sub.func.id}` "
                              f"(donate_argnums={donate})")


def _check_attr_use_after_donate(f, method, attr_donate, add):
    returned = _in_return(method)
    for sub in ast.walk(method):
        if id(sub) in returned:
            continue
        if isinstance(sub, ast.Call) and \
                isinstance(sub.func, ast.Attribute) and \
                isinstance(sub.func.value, ast.Name) and \
                sub.func.value.id == "self" and \
                sub.func.attr in attr_donate:
            donate = attr_donate[sub.func.attr]
            donated_args = [a.id for i, a in enumerate(sub.args)
                            if i in donate and isinstance(a, ast.Name)]
            _flag_reads_after(f, method, sub, donated_args, add,
                              f"jitted `self.{sub.func.attr}` "
                              f"(donate_argnums={donate})")
